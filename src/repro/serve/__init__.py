# Online multi-tenant serving layer: live tenant arrival/departure against a
# running PEFTEngine — admission (Eq. 5 memory + saturation gate), bounded
# priority wait queue, incremental re-planning with compiled-step reuse,
# adapter lifecycle (hot-attach, checkpoint-out, warm-start), and SLO-aware
# token-level co-serving of inference decode traffic next to fine-tuning.
from repro.serve.admission import (  # noqa: F401
    AdmissionConfig,
    AdmissionController,
    AdmissionDecision,
    WaitQueue,
)
from repro.serve.service import (  # noqa: F401
    CANCELLED,
    COMPLETED,
    LOST,
    MIGRATED,
    MigrationTicket,
    MuxTuneService,
    QUEUED,
    REJECTED,
    RUNNING,
    TenantRecord,
)
from repro.serve.spec import (  # noqa: F401
    RequestSpec,
    TenantSpec,
)
from repro.serve.inference import (  # noqa: F401
    CoServeConfig,
    DecodeScheduler,
    InferenceRequest,
)
from repro.serve.replay import (  # noqa: F401
    arrival_to_task,
    replay_fleet,
    replay_trace,
    tiny_trace,
)
