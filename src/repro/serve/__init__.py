# Online multi-tenant serving layer: live tenant arrival/departure against a
# running PEFTEngine — admission (Eq. 5 memory + saturation gate), bounded
# priority wait queue, incremental re-planning with compiled-step reuse, and
# adapter lifecycle (hot-attach, checkpoint-out, warm-start).
from repro.serve.admission import (  # noqa: F401
    AdmissionConfig,
    AdmissionController,
    AdmissionDecision,
    WaitQueue,
)
from repro.serve.service import (  # noqa: F401
    CANCELLED,
    COMPLETED,
    MuxTuneService,
    QUEUED,
    REJECTED,
    RUNNING,
    TenantRecord,
)
from repro.serve.replay import (  # noqa: F401
    arrival_to_task,
    replay_trace,
    tiny_trace,
)
