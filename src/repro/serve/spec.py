"""Unified submission specs (PR 10 API redesign).

``TenantSpec`` replaces the growing positional kwargs on
``MuxTuneService.submit`` / ``FleetRouter.submit`` (``priority``,
``target_steps``, ``warm_start_dir``, ``backbone``, ...), and
``RequestSpec`` the sampling/SLO knobs on ``submit_request``.  Both are
frozen: a spec is a durable submission record — the fleet router keeps the
specs it admitted tenants under, and crash recovery re-creates tenants and
in-flight requests from those records alone (the dead instance is never
asked anything).

The legacy kwargs form keeps working for one release through the
``coerce_*`` helpers (DeprecationWarning, once per call site name).
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.task import PEFTTask

_WARNED: set = set()

_TENANT_KEYS = ("priority", "target_steps", "warm_start_dir", "backbone")
_REQUEST_KEYS = ("max_new_tokens", "request_id", "temperature", "top_k",
                 "top_p", "seed", "slo_class")


def _warn_legacy(caller: str, hint: str) -> None:
    if caller in _WARNED:
        return
    _WARNED.add(caller)
    warnings.warn(
        f"{caller} with positional/keyword submission args is deprecated "
        f"(one release, PR 10); pass {hint} instead.",
        DeprecationWarning, stacklevel=4)


@dataclass(frozen=True)
class TenantSpec:
    """Everything a tenant submission says: the task plus placement and
    lifecycle knobs.  ``backbone`` only matters fleet-side (instance-label
    routing); a single service ignores it."""

    task: PEFTTask
    priority: int = 0
    target_steps: int = 10
    warm_start_dir: Optional[str] = None
    backbone: Optional[str] = None

    @property
    def task_id(self) -> str:
        return self.task.task_id


@dataclass(frozen=True)
class RequestSpec:
    """Sampling + SLO knobs of one inference request.  ``prompt`` is stored
    as an immutable tuple of token ids so the spec can serve as the durable
    record a crashed request is re-created from."""

    prompt: Tuple[int, ...]
    max_new_tokens: int = 8
    request_id: Optional[str] = None
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    slo_class: int = 0

    def __post_init__(self):
        object.__setattr__(
            self, "prompt",
            tuple(int(t) for t in np.asarray(self.prompt).reshape(-1)))

    def prompt_array(self) -> np.ndarray:
        return np.asarray(self.prompt, np.int32).reshape(-1)


def coerce_tenant_spec(spec, kwargs: Dict, caller: str) -> TenantSpec:
    """Accept a TenantSpec (new API) or a PEFTTask + legacy kwargs (old
    API, deprecation-warned once per caller)."""
    if isinstance(spec, TenantSpec):
        if kwargs:
            raise TypeError(
                f"{caller}: keyword args {sorted(kwargs)} are not accepted "
                f"alongside a TenantSpec — set them on the spec")
        return spec
    bad = set(kwargs) - set(_TENANT_KEYS)
    if bad:
        raise TypeError(f"{caller}: unknown submission args {sorted(bad)}")
    _warn_legacy(caller, "TenantSpec(task, priority=..., target_steps=...)")
    return TenantSpec(task=spec, **kwargs)


def coerce_request_spec(prompt_or_spec, kwargs: Dict,
                        caller: str) -> RequestSpec:
    """Accept a RequestSpec (new API) or a raw prompt + legacy kwargs."""
    if isinstance(prompt_or_spec, RequestSpec):
        if kwargs:
            raise TypeError(
                f"{caller}: keyword args {sorted(kwargs)} are not accepted "
                f"alongside a RequestSpec — set them on the spec")
        return prompt_or_spec
    bad = set(kwargs) - set(_REQUEST_KEYS)
    if bad:
        raise TypeError(f"{caller}: unknown request args {sorted(bad)}")
    _warn_legacy(caller, "RequestSpec(prompt, max_new_tokens=..., seed=...)")
    return RequestSpec(prompt=prompt_or_spec, **kwargs)
