"""Logical-axis sharding rules (DP/TP/SP/EP/CP) for the production mesh.

Models annotate tensors with *logical* axis names ("batch", "seq", "heads",
"ff", "vocab", "experts", ...).  A :class:`ShardingRules` maps each logical
name to a mesh axis (or tuple of axes, or ``None`` for replicated).  The
mapping is what the perf hillclimb iterates on — models never hard-code mesh
axes.

``activate_rules`` installs rules + mesh in a context; ``shard(x, *axes)``
then applies ``jax.lax.with_sharding_constraint``.  With no active rules the
call is the identity, so all model code runs unmodified on one device.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field, replace
from typing import Any, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisTarget = Union[None, str, Tuple[str, ...]]


@dataclass(frozen=True)
class ShardingRules:
    """Logical axis name -> mesh axis target.

    The defaults implement the baseline layout described in DESIGN.md §5:
    batch over ("pod","data"), sequence-parallel residual + CP attention over
    "model", Megatron TP for MLP/vocab/experts over "model".
    """

    rules: Tuple[Tuple[str, AxisTarget], ...] = (
        ("batch", ("pod", "data")),
        ("seq", "model"),          # sequence-sharded residual stream (SP)
        ("kv_seq", None),          # attention KV after gather: replicated
        ("heads", "model"),        # head-sharded attention (heads mode)
        ("kv_heads", None),
        ("head_dim", None),
        ("embed", None),
        ("ff", "model"),           # MLP TP
        ("vocab", "model"),        # vocab-sharded embedding + logits
        ("experts", "model"),      # expert parallelism
        ("expert_ff", None),
        ("cache_batch", ("pod", "data")),
        ("cache_seq", None),
        ("ssm_inner", "model"),
        ("ssm_heads", "model"),
        ("ssm_state", None),
        ("layers", None),          # stacked-scan leading dim
        ("stage", None),
        # adapter-stack task dim: optimizer moments shard across DP ranks
        # (per-tenant state scales with tenant count, not model size)
        ("adapter_tasks", ("pod", "data")),
    )

    def lookup(self, name: Optional[str]) -> AxisTarget:
        if name is None:
            return None
        for k, v in self.rules:
            if k == name:
                return v
        return None

    def with_updates(self, **kw: AxisTarget) -> "ShardingRules":
        d = dict(self.rules)
        d.update(kw)
        return ShardingRules(tuple(d.items()))

    # rule keys whose values are execution flags, not mesh axes
    FLAG_KEYS = ("moe_impl", "moe_wire", "attn_impl")

    def mesh_axes(self, mesh: Mesh) -> "ShardingRules":
        """Drop rule targets that reference axes absent from ``mesh``
        (e.g. "pod" on the single-pod mesh).  Flag-valued keys pass through."""
        names = set(mesh.axis_names)

        def fix(k: str, t: AxisTarget) -> AxisTarget:
            if k in self.FLAG_KEYS or t is None:
                return t
            if isinstance(t, str):
                return t if t in names else None
            kept = tuple(a for a in t if a in names)
            return kept if kept else None

        return ShardingRules(tuple((k, fix(k, v)) for k, v in self.rules))


class _Env(threading.local):
    def __init__(self) -> None:
        self.mesh: Optional[Mesh] = None
        self.rules: Optional[ShardingRules] = None


_ENV = _Env()


@contextlib.contextmanager
def activate_rules(mesh: Optional[Mesh], rules: Optional[ShardingRules]):
    """Install (mesh, rules) for ``shard`` calls inside the context."""
    prev = (_ENV.mesh, _ENV.rules)
    _ENV.mesh = mesh
    _ENV.rules = rules.mesh_axes(mesh) if (rules is not None and mesh is not None) else rules
    try:
        yield
    finally:
        _ENV.mesh, _ENV.rules = prev


def active_rules() -> Tuple[Optional[Mesh], Optional[ShardingRules]]:
    return _ENV.mesh, _ENV.rules


def logical_to_spec(axes: Sequence[Optional[str]], rules: ShardingRules) -> P:
    """Translate logical axes (one per tensor dim) to a PartitionSpec.

    A mesh axis may appear at most once in a PartitionSpec; later duplicate
    uses fall back to replicated for that dim.
    """
    used: set = set()
    out = []
    for name in axes:
        target = rules.lookup(name)
        if target is None:
            out.append(None)
            continue
        tgt = (target,) if isinstance(target, str) else tuple(target)
        free = tuple(a for a in tgt if a not in used)
        if len(free) != len(tgt):
            out.append(None)
            continue
        used.update(free)
        out.append(free[0] if len(free) == 1 else free)
    return P(*out)


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Constrain ``x``'s sharding by logical axes; identity w/o active rules."""
    mesh, rules = _ENV.mesh, _ENV.rules
    if mesh is None or rules is None:
        return x
    if x.ndim != len(axes):
        raise ValueError(f"shard(): rank {x.ndim} vs {len(axes)} logical axes")
    spec = logical_to_spec(axes, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(mesh: Mesh, rules: ShardingRules, axes: Sequence[Optional[str]]) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(axes, rules.mesh_axes(mesh)))


def divisible(dim: int, mesh: Mesh, target: AxisTarget) -> bool:
    """Whether ``dim`` divides evenly over the mesh axes in ``target``."""
    if target is None:
        return True
    tgt = (target,) if isinstance(target, str) else target
    size = 1
    for a in tgt:
        if a in mesh.axis_names:
            size *= mesh.shape[a]
    return dim % size == 0
