"""Pipeline parallelism: collective-permute pipeline driven by the MuxTune
structured template (§3.4.1).

Realization: the classic JAX "collective pipeline" — stage-stacked params
live on a ``stage`` mesh axis inside ``shard_map``; one scan over clocks
advances every stage in parallel and moves activations to the next stage
with ``jax.lax.ppermute``.  Reverse-mode AD through the scan+ppermute yields
the backward pipeline automatically; with PEFT's fwd==bwd stage latency the
resulting schedule matches the paper's symmetric-1F1B timing model, and the
*order* in which micro-batches are fed is exactly the planner's template
(buckets sorted desc, consecutive micro-batches) — the template is data,
not code.

``pipeline_reference`` runs the same clock loop without shard_map (single
device) for semantics tests; the shard_map path is exercised by the
dry-run at mesh scale.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P


def _clock_loop(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,  # leaves stacked [n_stages, ...] (or per-shard [1, ...])
    microbatches: jax.Array,  # [n_micro, mb, ...]
    n_stages: int,
    shift: Callable[[jax.Array], jax.Array],
    select_stage: Callable[[Any, int], Any],
    my_stage: Optional[jax.Array] = None,
):
    n_micro = microbatches.shape[0]
    clocks = n_micro + n_stages - 1
    mb_shape = microbatches.shape[1:]
    state = jnp.zeros((1,) + mb_shape, microbatches.dtype) if my_stage is not None else jnp.zeros(
        (n_stages,) + mb_shape, microbatches.dtype
    )
    outputs = jnp.zeros((n_micro,) + mb_shape, microbatches.dtype)

    def clock(carry, t):
        state, outputs = carry
        # inject the next microbatch at stage 0
        inject = jnp.where(t < n_micro, 1, 0)
        mb = jax.lax.dynamic_index_in_dim(
            microbatches, jnp.minimum(t, n_micro - 1), axis=0, keepdims=False
        )
        if my_stage is not None:  # shard_map path: local slice is [1, ...]
            is_first = (my_stage == 0)
            cur = jnp.where(is_first & (inject == 1), mb[None], state)
            y = stage_fn(select_stage(stage_params, 0), cur[0])[None]
        else:  # reference path: vmap over all stages
            cur = state.at[0].set(jnp.where(inject == 1, mb, state[0]))
            y = jax.vmap(stage_fn)(stage_params, cur)
        out_mb = t - (n_stages - 1)
        if my_stage is not None:
            last_y = y[0]
            take = (my_stage == n_stages - 1) & (out_mb >= 0)
            outputs = jax.lax.cond(
                take,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, last_y, jnp.maximum(out_mb, 0), axis=0),
                lambda o: o,
                outputs,
            )
        else:
            outputs = jax.lax.cond(
                out_mb >= 0,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y[-1], jnp.maximum(out_mb, 0), axis=0),
                lambda o: o,
                outputs,
            )
        state = shift(y)
        return (state, outputs), None

    (state, outputs), _ = jax.lax.scan(clock, (state, outputs), jnp.arange(clocks))
    return outputs


def pipeline_reference(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,  # [n_stages, ...]
    microbatches: jax.Array,
    n_stages: int,
) -> jax.Array:
    """Single-device clock-accurate reference (for tests)."""

    def shift(y):
        return jnp.concatenate([jnp.zeros_like(y[:1]), y[:-1]], axis=0)

    return _clock_loop(stage_fn, stage_params, microbatches, n_stages, shift,
                       select_stage=lambda p, i: p)


def pipeline_shard_map(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,  # leaves stacked [n_stages, ...]
    microbatches: jax.Array,  # [n_micro, mb, ...]
    mesh: Mesh,
    stage_axis: str = "stage",
) -> jax.Array:
    """shard_map pipeline over ``stage_axis`` with ppermute transfers."""
    n_stages = mesh.shape[stage_axis]

    def body(params_local, micro):
        my_stage = jax.lax.axis_index(stage_axis)

        def shift(y):
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            return jax.lax.ppermute(y, stage_axis, perm)

        outs = _clock_loop(
            stage_fn, params_local, micro, n_stages, shift,
            select_stage=lambda p, i: jax.tree.map(lambda a: a[i], p),
            my_stage=my_stage,
        )
        # only the last stage holds real outputs; broadcast via psum of mask
        is_last = (my_stage == n_stages - 1).astype(outs.dtype)
        return jax.lax.psum(outs * is_last, stage_axis)

    pspec = jax.tree.map(lambda _: P(stage_axis), stage_params)
    from repro.compat import shard_map

    return shard_map(
        body, mesh=mesh,
        in_specs=(pspec, P()),
        out_specs=P(),
        check_vma=False,
    )(stage_params, microbatches)


def pipeline_loss(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    loss_fn: Callable[[jax.Array], jax.Array],
    stage_params: Any,
    microbatches: jax.Array,
    n_stages: int,
    mesh: Optional[Mesh] = None,
    stage_axis: str = "stage",
) -> jax.Array:
    """End-to-end pipelined loss (AD through it = backward pipeline)."""
    if mesh is not None and stage_axis in mesh.axis_names and mesh.shape[stage_axis] > 1:
        outs = pipeline_shard_map(stage_fn, stage_params, microbatches, mesh, stage_axis)
    else:
        outs = pipeline_reference(stage_fn, stage_params, microbatches, n_stages)
    return loss_fn(outs)
