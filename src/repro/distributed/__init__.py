from repro.distributed.checkpoint import (  # noqa: F401
    AsyncCheckpointer,
    CheckpointStore,
    restore_latest,
    save_checkpoint,
)
from repro.distributed.sharding import (  # noqa: F401
    ShardingRules,
    activate_rules,
    active_rules,
    shard,
    logical_to_spec,
    named_sharding,
)
