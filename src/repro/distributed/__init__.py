from repro.distributed.sharding import (  # noqa: F401
    ShardingRules,
    activate_rules,
    active_rules,
    shard,
    logical_to_spec,
    named_sharding,
)
