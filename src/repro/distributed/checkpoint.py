"""Checkpointing: atomic, async, content-verified, elastic-reshardable.

Layout (one directory per step):
    <dir>/step_<n>.tmp/...   -> written, fsynced, manifest-hashed
    <dir>/step_<n>/          -> atomic rename commits the checkpoint

Every leaf is a raw ``.npy`` plus a JSON manifest carrying the tree
structure, dtypes, shapes and a crc32 per leaf — restore verifies
integrity, so a preempted/partial write can never be loaded (fault
tolerance requirement).  ``AsyncCheckpointer`` moves serialization off the
training thread.  Restore is *elastic*: arrays are loaded host-side and
``jax.device_put`` with the NEW mesh's NamedShardings — a checkpoint saved
on mesh A restores onto mesh B (different axis sizes) as long as the
logical shapes match, which is what elastic scaling needs.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree: Any) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path)
        out.append((key, leaf))
    return out, treedef


def save_checkpoint(directory: str, step: int, tree: Any, extra: Optional[Dict] = None) -> str:
    """Atomic checkpoint write; returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, _ = _flatten_with_paths(tree)
    manifest: Dict[str, Any] = {"step": step, "leaves": {}, "extra": extra or {},
                                "time": time.time()}
    for i, (key, leaf) in enumerate(flat):
        arr = np.asarray(leaf)
        orig_dtype = str(arr.dtype)
        if arr.dtype.kind in ("V",) or orig_dtype in ("bfloat16", "float8_e4m3fn", "float8_e5m2"):
            # numpy can't serialize ml_dtypes natively; widen losslessly
            arr = np.asarray(leaf, dtype=np.float32)
        fname = f"leaf_{i:05d}.npy"
        path = os.path.join(tmp, fname)
        with open(path, "wb") as f:
            np.save(f, arr)
            f.flush()
            os.fsync(f.fileno())
        manifest["leaves"][key] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": orig_dtype,
            "crc32": zlib.crc32(arr.tobytes()),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str,
    step: int,
    like: Any,
    shardings: Any = None,
    verify: bool = True,
    strict_shapes: bool = True,
) -> Tuple[Any, Dict]:
    """Restore into the structure of ``like``; optionally device_put with
    ``shardings`` (elastic: any mesh whose shardings fit the logical shapes).

    ``strict_shapes=False`` keeps the structural (leaf-key) contract but
    returns each leaf at its SAVED shape — the warm-start path needs this
    because an adapter slice checkpointed out of one tenant cohort can be
    rank-padded wider or narrower than the restoring stack's slot, and the
    slot writer (``load_task_tree``) owns the shape-adaptation rules."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat, treedef = _flatten_with_paths(like)
    shard_flat = None
    if shardings is not None:
        sf, _ = _flatten_with_paths(shardings)
        shard_flat = dict(sf)
    leaves = []
    for key, leaf in flat:
        meta = manifest["leaves"].get(key)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = np.load(os.path.join(path, meta["file"]))
        if verify and zlib.crc32(arr.tobytes()) != meta["crc32"]:
            raise IOError(f"checksum mismatch for {key} — corrupt checkpoint")
        if strict_shapes and list(arr.shape) != list(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {np.shape(leaf)}")
        if str(arr.dtype) != meta["dtype"]:
            import ml_dtypes  # lossless narrow back (bf16 saved as f32)

            arr = arr.astype(np.dtype(getattr(ml_dtypes, meta["dtype"], meta["dtype"])))
        if shard_flat is not None and key in shard_flat:
            leaves.append(jax.device_put(arr, shard_flat[key]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, leaves), manifest["extra"]


def restore_latest(
    directory: str,
    like: Any,
    shardings: Any = None,
    verify: bool = True,
    strict_shapes: bool = True,
) -> Optional[Tuple[int, Any, Dict]]:
    """Restore the newest committed checkpoint in ``directory`` (or None if
    the directory holds none) — the warm-start entry point for a tenant
    resubmitting a previously checkpointed-out adapter."""
    step = latest_step(directory)
    if step is None:
        return None
    tree, extra = restore_checkpoint(directory, step, like, shardings, verify,
                                     strict_shapes)
    return step, tree, extra


def prune_checkpoints(directory: str, keep: int = 3) -> None:
    if not os.path.isdir(directory):
        return
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(directory)
        if n.startswith("step_") and not n.endswith(".tmp")
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)


class CheckpointStore:
    """THE checkpoint surface (PR 10): one root directory of atomic
    ``step_<n>`` artifacts with synchronous and background-thread writes,
    latest-committed reads, manifest-only metadata reads, and the
    ``strict_shapes`` restore contract.

    Before PR 10 three near-copies of this logic existed — the training
    supervisor's ``AsyncCheckpointer``, ``MuxTuneService.checkpoint_out_tenant``
    and the ``MigrationTicket`` artifact directory.  They all route through
    one store now, so migration warm-start, completed-tenant resubmission
    and crash recovery read and write the exact same layout.

    Crash consistency: a reader only ever sees directories that finished
    the tmp-then-rename commit, so ``restore_latest``/``read_extra`` after
    a mid-write kill observe the previous committed step, never a torn one.
    """

    def __init__(self, root: str, keep: int = 0):
        self.root = root
        self.keep = keep                     # 0 = keep every step
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- writes -----------------------------------------------------------

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> str:
        """Synchronous atomic write; returns the committed path."""
        path = save_checkpoint(self.root, step, tree, extra)
        if self.keep:
            prune_checkpoints(self.root, self.keep)
        return path

    def save_async(self, step: int, tree: Any,
                   extra: Optional[Dict] = None) -> None:
        """Host-copy now (one device sync), serialize on a background
        thread — the training loop never blocks on file IO.  Saves are
        ordered: a still-running previous save is joined first, and its
        error (if any) surfaces here or on the next ``wait()``."""
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # sync copy out of device

        def work():
            try:
                self.save(step, host_tree, extra)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        """Join the in-flight background save (re-raising its error)."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # -- reads ------------------------------------------------------------

    def latest_step(self) -> Optional[int]:
        return latest_step(self.root)

    def read_extra(self, step: Optional[int] = None) -> Optional[Dict]:
        """Manifest-only read of a committed artifact's ``extra`` record (no
        leaf IO, no ``like`` tree needed) — crash recovery plans from this
        before it knows what shapes the restoring stack will open."""
        step = self.latest_step() if step is None else step
        if step is None:
            return None
        path = os.path.join(self.root, f"step_{step:08d}", "manifest.json")
        with open(path) as f:
            return json.load(f).get("extra", {})

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None, verify: bool = True,
                strict_shapes: bool = True
                ) -> Optional[Tuple[int, Any, Dict]]:
        """(step, tree, extra) of ``step`` — default the latest committed —
        or None when the store holds no committed artifact."""
        if step is None:
            return restore_latest(self.root, like, shardings, verify,
                                  strict_shapes)
        tree, extra = restore_checkpoint(self.root, step, like, shardings,
                                         verify, strict_shapes)
        return step, tree, extra

    def prune(self, keep: Optional[int] = None) -> None:
        prune_checkpoints(self.root, keep if keep is not None else
                          (self.keep or 3))


class AsyncCheckpointer:
    """Back-compat facade over :class:`CheckpointStore` (pre-PR-10 API:
    ``save`` is the ASYNC write).  New code should use the store directly."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self.store = CheckpointStore(directory, keep=keep)

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> None:
        self.store.save_async(step, tree, extra)

    def wait(self) -> None:
        self.store.wait()
