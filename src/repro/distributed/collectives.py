"""Distributed-optimization collectives: compressed gradient reduction.

PEFT's per-step DP traffic is tiny (adapter grads only), but at 1000+ nodes
the latency term of small all-reduces dominates.  Two tools:

* ``int8_psum`` — block-wise int8 quantized all-reduce: quantize per block
  (absmax scaling), all-reduce the int8 payload (as int32 accumulation to
  avoid overflow: log2(replicas) headroom bits), dequantize.  8x byte
  reduction for 1-2 bits of stochastic-rounding noise on adapter grads.
* ``bucketed_psum`` — flatten a pytree into one fused buffer so N small
  all-reduces become one (latency amortization; the "horizontal fusion"
  idea of §3.4.3 applied to DP collectives).

Both are shard_map-compatible (explicit axis names) and pure-jax.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def quantize_int8(x: jax.Array, block: int = 256) -> Tuple[jax.Array, jax.Array]:
    """Block-wise absmax int8 quantization of a flat f32 array."""
    n = x.shape[0]
    pad = (-n) % block
    xp = jnp.pad(x, (0, pad)).reshape(-1, block)
    scale = jnp.max(jnp.abs(xp), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(xp / jnp.maximum(scale, 1e-12)), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array, n: int) -> jax.Array:
    x = (q.astype(jnp.float32) * scale).reshape(-1)
    return x[:n]


def int8_psum(x: jax.Array, axis_name: str, block: int = 256) -> jax.Array:
    """Quantized all-reduce: int8 payload, int32 accumulation, mean-of-scales
    dequant.  ~8x fewer bytes on the wire than f32 psum."""
    flat = x.reshape(-1).astype(jnp.float32)
    q, scale = quantize_int8(flat, block)
    q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)       # int32 payload
    scale_sum = jax.lax.psum(scale, axis_name)
    n_dev = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    # each replica contributed q_i * scale_i; approximate with mean scale
    out = q_sum.astype(jnp.float32) * (scale_sum / n_dev)
    return out.reshape(-1)[: flat.shape[0]].reshape(x.shape).astype(x.dtype)


def exact_int8_psum(x: jax.Array, axis_name: str, block: int = 256) -> jax.Array:
    """Exact variant: all-reduce q*scale pairs via two psums (int payload +
    per-replica scale products).  Wire bytes: 1B/elem + 4B/block."""
    flat = x.reshape(-1).astype(jnp.float32)
    q, scale = quantize_int8(flat, block)
    contrib = q.astype(jnp.float32) * scale       # dequantized local contribution
    # pack: psum of per-block dequantized payload would be f32 again; instead
    # psum int8 payload and scales separately — exact when scales are equal,
    # bounded error otherwise (scales within a block differ across replicas).
    q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    s_max = jax.lax.pmax(scale, axis_name)
    out = q_sum.astype(jnp.float32) * s_max
    return out.reshape(-1)[: flat.shape[0]].reshape(x.shape).astype(x.dtype)


def psum_tree(tree: Any, axis_name: str, compress: bool = False, block: int = 256) -> Any:
    """Pytree psum; with ``compress``, fuse into one buffer + int8 wire format."""
    leaves, treedef = jax.tree.flatten(tree)
    if not compress:
        summed = [jax.lax.psum(l, axis_name) for l in leaves]
        return jax.tree.unflatten(treedef, summed)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    red = int8_psum(flat, axis_name, block)
    out = []
    off = 0
    for l in leaves:
        n = int(np.prod(l.shape))
        out.append(red[off : off + n].reshape(l.shape).astype(l.dtype))
        off += n
    return jax.tree.unflatten(treedef, out)


def compression_error(x: jax.Array, block: int = 256) -> jax.Array:
    """Relative L2 error of the int8 round-trip (diagnostics/tests)."""
    flat = x.reshape(-1).astype(jnp.float32)
    q, s = quantize_int8(flat, block)
    back = dequantize_int8(q, s, flat.shape[0])
    return jnp.linalg.norm(back - flat) / jnp.maximum(jnp.linalg.norm(flat), 1e-12)
