"""Fault tolerance: restart supervision, straggler mitigation, elasticity.

At 1000+ nodes the failure model is: (a) hard node loss -> restart from the
latest committed checkpoint, possibly on a different mesh (elastic); (b)
slow node (straggler) -> deterministic re-dispatch of its micro-batches;
(c) preemption -> same as (a) with the async checkpointer bounding loss to
one save interval.

Design points realized here:
 * ``TrainSupervisor`` — wraps the step loop: periodic async checkpoints,
   crash/restart recovery (``resume()``), bounded retry with simulated or
   real failure injection (tests inject via ``failure_hook``).
 * ``StragglerMitigator`` — per-host step-time EWMA; hosts slower than
   ``threshold`` x median get their micro-batches re-dispatched to the
   fastest hosts next iteration.  With MuxTune's static bucket templates the
   re-dispatch is a permutation of the (host, micro-batch) table, so shapes
   and compiled steps are untouched — re-planning is O(hosts log hosts).
 * ``ElasticPlanner`` — the shrunk-capacity brain: recomputes the
   ParallelismSpec for a changed chip count (checkpoint restore handles the
   data move) and, for the fleet tier, orders and drives the re-admission
   of tenants orphaned by a hard instance loss onto surviving capacity.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.task import ParallelismSpec
from repro.distributed.checkpoint import (
    CheckpointStore,
    latest_step,
    restore_checkpoint,
)


@dataclass
class SupervisorConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    max_restarts: int = 3


class TrainSupervisor:
    """Checkpoint/restart harness around a step function.

    ``step_fn(state, step_idx) -> state`` must be pure in ``state``.
    ``failure_hook(step_idx)`` may raise to simulate node failures.
    """

    def __init__(self, cfg: SupervisorConfig,
                 failure_hook: Optional[Callable[[int], None]] = None):
        self.cfg = cfg
        self.ckpt = CheckpointStore(cfg.ckpt_dir, keep=cfg.keep)
        self.failure_hook = failure_hook
        self.restarts = 0

    def resume(self, init_state: Any, shardings: Any = None) -> Tuple[Any, int]:
        step = latest_step(self.cfg.ckpt_dir)
        if step is None:
            return init_state, 0
        state, extra = restore_checkpoint(
            self.cfg.ckpt_dir, step, init_state, shardings
        )
        return state, int(extra.get("next_step", step + 1))

    def run(
        self,
        init_state: Any,
        step_fn: Callable[[Any, int], Any],
        n_steps: int,
        shardings: Any = None,
    ) -> Any:
        state, start = self.resume(init_state, shardings)
        i = start
        while i < n_steps:
            try:
                if self.failure_hook is not None:
                    self.failure_hook(i)
                state = step_fn(state, i)
                i += 1
                if i % self.cfg.ckpt_every == 0 or i == n_steps:
                    self.ckpt.save_async(i, state, extra={"next_step": i})
            except _SimulatedFailure:
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise
                self.ckpt.wait()
                state, i = self.resume(init_state, shardings)
        self.ckpt.wait()
        return state


class _SimulatedFailure(RuntimeError):
    pass


def simulated_failure() -> BaseException:
    return _SimulatedFailure("injected node failure")


# ---------------------------------------------------------------------------
# Straggler mitigation
# ---------------------------------------------------------------------------


@dataclass
class HostStat:
    ewma: float = 0.0
    n: int = 0


class StragglerMitigator:
    """Detects slow hosts and re-balances their micro-batch assignment.

    The assignment is a table host -> list of (bucket, micro) ids; shapes
    are bucket-static so moving a micro-batch between hosts needs no
    recompilation (the compiled step is shared)."""

    def __init__(self, n_hosts: int, threshold: float = 1.5, alpha: float = 0.3):
        self.stats = [HostStat() for _ in range(n_hosts)]
        self.threshold = threshold
        self.alpha = alpha

    def observe(self, host: int, step_seconds: float) -> None:
        s = self.stats[host]
        s.ewma = step_seconds if s.n == 0 else (1 - self.alpha) * s.ewma + self.alpha * step_seconds
        s.n += 1

    def stragglers(self) -> List[int]:
        times = [s.ewma for s in self.stats if s.n > 0]
        if len(times) < 2:
            return []
        med = float(np.median(times))
        return [i for i, s in enumerate(self.stats)
                if s.n > 0 and s.ewma > self.threshold * med]

    def rebalance(self, assignment: Dict[int, List[Any]]) -> Dict[int, List[Any]]:
        """Move work from stragglers to the fastest hosts, proportionally."""
        slow = set(self.stragglers())
        if not slow:
            return assignment
        fast = sorted(
            (h for h in assignment if h not in slow),
            key=lambda h: self.stats[h].ewma if self.stats[h].n else math.inf,
        )
        if not fast:
            return assignment
        out = {h: list(v) for h, v in assignment.items()}
        for h in slow:
            med = float(np.median([s.ewma for s in self.stats if s.n > 0]))
            excess_frac = 1.0 - med / self.stats[h].ewma
            n_move = int(len(out[h]) * excess_frac)
            for k in range(n_move):
                if out[h]:
                    out[fast[k % len(fast)]].append(out[h].pop())
        return out


# ---------------------------------------------------------------------------
# Elastic scaling
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RecoveryAction:
    """One planned step of elastic recovery after an instance loss."""

    tenant_id: str
    action: str                  # "readmit" | "queue"
    target: Optional[int] = None  # instance the tenant landed on (readmit)


class ElasticPlanner:
    """Decides how training continues when capacity shrinks.

    Two consumers:

    * the single-engine world asks :meth:`respec` for the ParallelismSpec of
      a changed chip count (checkpoint restore handles the data move);
    * the fleet router asks :meth:`plan_recovery` to drive re-admission of
      the tenants orphaned by a hard instance loss onto survivors — highest
      priority first, then most training progress (when not everyone fits,
      the tenants with the most sunk work are placed before the shrunk
      capacity runs out), leftovers explicitly queued rather than dropped.
    """

    def __init__(self, prefer_tp: int = 1):
        self.prefer_tp = prefer_tp

    def respec(self, old: ParallelismSpec,
               new_total_chips: int) -> ParallelismSpec:
        return elastic_respec(old, new_total_chips, self.prefer_tp)

    def recovery_order(
        self, orphans: Sequence[Tuple[str, int, int]]) -> List[str]:
        """Re-admission order for ``(tenant_id, priority, steps_trained)``
        triples: priority desc, steps trained desc, id for determinism."""
        return [tid for tid, _, _ in
                sorted(orphans, key=lambda o: (-o[1], -o[2], o[0]))]

    def plan_recovery(
        self,
        orphans: Sequence[Tuple[str, int, int]],
        place: Callable[[str], Optional[int]],
    ) -> List[RecoveryAction]:
        """Drive recovery: call ``place(tenant_id)`` for each orphan in
        recovery order.  ``place`` performs the actual re-admission and
        returns the landing instance id, or None when nothing feasible is
        left (the caller queues the tenant).  Placement mutates capacity,
        so the callback runs strictly in plan order."""
        out: List[RecoveryAction] = []
        for tid in self.recovery_order(orphans):
            target = place(tid)
            out.append(RecoveryAction(
                tid, "readmit" if target is not None else "queue", target))
        return out


def elastic_respec(
    old: ParallelismSpec, new_total_chips: int, prefer_tp: int
) -> ParallelismSpec:
    """Recompute the parallelism spec for a changed chip count.

    Keeps TP at ``prefer_tp`` when divisible (weights reshard cheaply along
    unchanged axes); folds the rest into stages/data."""
    tp = prefer_tp if new_total_chips % prefer_tp == 0 else math.gcd(new_total_chips, prefer_tp)
    rest = new_total_chips // tp
    stages = min(old.num_stages, rest)
    while rest % stages:
        stages -= 1
    return ParallelismSpec(
        num_stages=stages,
        chips_per_stage=new_total_chips // stages,
        tp=tp,
        dp=rest // stages,
    )
