"""SLO-aware co-serving: inference decode traffic next to fine-tuning.

Two tenants fine-tune against ONE multiplexed backbone while the service
answers inference requests against their live adapter stacks — alice is
LoRA, bob is prefix-tuning (his learned k/v rows are folded into the KV
cache at bind/prefill time).  Decode tokens are packed into each training
iteration under the latency SLO, and the run proves training-loss parity
against an identical traffic-free service.

  PYTHONPATH=src python examples/coserve.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs import smoke_config
from repro.core.task import ParallelismSpec
from repro.data.synthetic import make_task
from repro.peft.adapters import LORA, PREFIX_TUNING
from repro.peft.methods import AdapterConfig
from repro.serve import CoServeConfig, MuxTuneService

STEPS = 6


def make_service():
    cfg = smoke_config("llama3.2-3b")
    return MuxTuneService(
        cfg, ParallelismSpec(), lr=5e-3, n_micro=1, enable_fusion=False,
        reserve_slots=4, auto_recalibrate=False,
        coserve=CoServeConfig(decode_slots=2, decode_max_len=32,
                              max_new_cap=8, slo_seconds=1.0))


def submit_tenants(svc):
    svc.submit(make_task("alice", "sst2", 2, AdapterConfig(LORA, rank=8),
                         seed=0), target_steps=STEPS)
    svc.submit(make_task("bob", "qa", 2, AdapterConfig(PREFIX_TUNING, rank=4),
                         seed=1), target_steps=STEPS)


def main():
    print("== reference run: 2 training tenants, NO inference traffic ==")
    ref = make_service()
    submit_tenants(ref)
    ref_losses = [np.asarray(ref.step().per_task_loss) for _ in range(STEPS)]

    print("== co-serve run: same tenants + decode requests interleaved ==")
    svc = make_service()
    submit_tenants(svc)
    svc.submit_request("alice", [11, 23, 5], max_new_tokens=6)
    svc.submit_request("bob", [7, 3, 19, 2], max_new_tokens=5)
    svc.submit_request("alice", [42, 17], max_new_tokens=4)

    losses = []
    for _ in range(STEPS):
        m = svc.step()
        losses.append(np.asarray(m.per_task_loss))
        if m.decode_tokens:
            print(f"  t={svc.clock}: loss={m.loss:.3f}  "
                  f"decode={m.decode_tokens} tok in "
                  f"{m.decode_seconds * 1e3:.0f}ms "
                  f"({m.decode_token_seconds * 1e3:.1f}ms/tok)")

    for rid, req in svc.coserve.requests.items():
        gen = [] if req.tokens_out is None else req.tokens_out.tolist()
        print(f"  {rid}: {req.state}, prompt {len(req.prompt)} tok -> "
              f"generated {gen}")

    co = svc.accounting()["coserve"]
    print(f"== SLO metrics: {co['decode_tokens']} decode tokens, "
          f"p50 {co['decode_p50_s'] * 1e3:.1f}ms/tok, "
          f"p99 {co['decode_p99_s'] * 1e3:.1f}ms/tok, "
          f"{co['completed_requests']} requests completed ==")

    drift = np.max(np.abs(np.asarray(losses) / np.asarray(ref_losses) - 1.0))
    print(f"== training-loss parity vs traffic-free run: "
          f"max rel drift {drift:.2e} (tolerance 2e-4) ==")
    np.testing.assert_allclose(np.asarray(losses), np.asarray(ref_losses),
                               rtol=2e-4, atol=2e-4)
    # on a slow machine the SLO floor (1 token/iteration) may not drain all
    # three requests before the tenants complete — two must always finish
    assert co["completed_requests"] >= 2
    print("done.")


if __name__ == "__main__":
    main()
