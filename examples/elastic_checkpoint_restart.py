"""Fault tolerance demo: failure injection + restart + elastic respec.

1. Train with checkpoints; inject two simulated node failures — the
   supervisor restores from the latest committed checkpoint each time.
2. Restore the final adapter state under a DIFFERENT parallelism spec
   (elastic scaling) and verify bit-equality of the logical state.

  PYTHONPATH=src python examples/elastic_checkpoint_restart.py
"""
import sys, os, shutil
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.core import ExecutionPlanner, ModelGenerator, ParallelismSpec, PEFTEngine
from repro.core.task import ParallelismSpec as PSpec
from repro.data import HTaskLoader, make_task
from repro.distributed.checkpoint import latest_step, restore_checkpoint
from repro.distributed.fault_tolerance import (
    SupervisorConfig,
    TrainSupervisor,
    elastic_respec,
    simulated_failure,
)
from repro.peft.adapters import LORA
from repro.peft.methods import AdapterConfig

CKPT = "/tmp/muxtune_elastic_demo"


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    cfg = smoke_config("llama3.2-3b")
    tasks = [make_task(f"t{i}", ds, 1, AdapterConfig(LORA, rank=8), seed=i)
             for i, ds in enumerate(["sst2", "qa"])]
    planner = ExecutionPlanner(cfg, ParallelismSpec(num_stages=2, chips_per_stage=1))
    plan = planner.plan(tasks, n_micro=1)
    gen = ModelGenerator(cfg)
    gen.register_tasks(tasks)
    engine = PEFTEngine(gen, plan, lr=1e-3)
    loaders = {i: HTaskLoader(tasks, plan.alignment[i], cfg.vocab_size)
               for i in range(len(plan.htasks))}

    # inject failures at steps 4 and 9
    fails = {4: True, 9: True}

    def failure_hook(i):
        if fails.pop(i, False):
            print(f"  !! injected node failure at step {i}")
            raise simulated_failure()

    sup = TrainSupervisor(SupervisorConfig(ckpt_dir=CKPT, ckpt_every=3,
                                           max_restarts=5), failure_hook)

    def step_fn(state, i):
        engine.reg.adapter_params, engine.reg.opt_state = state
        m = engine.run_iteration(loaders)
        print(f"  step {i}: loss={m.loss:.3f}")
        return engine.reg.adapter_params, engine.reg.opt_state

    print("== training with failure injection ==")
    state = (engine.reg.adapter_params, engine.reg.opt_state)
    state = sup.run(state, step_fn, 12)
    print(f"  completed with {sup.restarts} restarts; "
          f"latest checkpoint: step {latest_step(CKPT)}")

    print("== elastic restore ==")
    old_spec = PSpec(num_stages=2, chips_per_stage=2, tp=2, dp=1)
    new_spec = elastic_respec(old_spec, new_total_chips=6, prefer_tp=2)
    print(f"  respec: {old_spec} -> {new_spec}")
    like = (engine.reg.adapter_params, engine.reg.opt_state)
    restored, extra = restore_checkpoint(CKPT, latest_step(CKPT), like)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("  restored state matches trained state bit-for-bit")
    print("done.")


if __name__ == "__main__":
    main()
