"""Fleet tier walkthrough: route, live-migrate, and autoscale across
multiple in-process MuxTune instances.

A 2-instance fleet admits three LoRA tenants with the best_fit policy
(every placement checked against the lockstep ClusterSim oracle), then
live-migrates one tenant mid-training — drain, atomic checkpoint-out,
warm-start with optimizer moments on the target — while one of its decode
requests is in flight.  The request survives the move and finishes with
the same seeded-sampling tokens it would have produced without migration,
and the tenant's loss trajectory continues exactly where it left off.

  PYTHONPATH=src python examples/fleet_demo.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs import smoke_config
from repro.core.task import ParallelismSpec
from repro.data.synthetic import make_task
from repro.fleet import Autoscaler, AutoscalerConfig, FleetRouter
from repro.peft.adapters import LORA
from repro.peft.methods import AdapterConfig
from repro.serve import CoServeConfig, MuxTuneService

STEPS = 6


def factory(iid):
    cfg = smoke_config("llama3.2-3b")
    return MuxTuneService(
        cfg, ParallelismSpec(), lr=5e-3, n_micro=1, enable_fusion=False,
        reserve_slots=4, auto_recalibrate=False, seed=0,
        coserve=CoServeConfig(max_tokens_per_iter=1))


def main():
    fleet = FleetRouter(factory, n_instances=2, policy="best_fit")
    # floor of 2 keeps the idle second instance alive as a migration target
    fleet.autoscaler = Autoscaler(AutoscalerConfig(min_instances=2,
                                                   max_instances=3))

    print("== admit three tenants (best_fit, oracle-checked) ==")
    for i, (tid, ds) in enumerate([("alice", "sst2"), ("bob", "qa"),
                                   ("carol", "rte")]):
        d = fleet.submit(make_task(tid, ds, 1, AdapterConfig(LORA, rank=4),
                                   seed=i), target_steps=STEPS)
        print(f"  {tid:5s} -> instance {d.instance} "
              f"(oracle {d.oracle}, {d.outcome})")

    print("== decode request against alice, then 2 training steps ==")
    req = fleet.submit_request("alice", np.arange(1, 6), max_new_tokens=6,
                               temperature=0.7, top_k=5, seed=11,
                               request_id="r0")
    for _ in range(2):
        fleet.step()
    rec = fleet.record("alice")
    print(f"  alice: {rec.steps_trained} steps, "
          f"losses {[f'{l:.4f}' for l in rec.losses]}; r0 {req.state}")

    print("== live-migrate alice (request r0 still in flight) ==")
    rep = fleet.migrate("alice")
    print(f"  moved {rep.source} -> {rep.target} in "
          f"{rep.wall_seconds * 1e3:.0f} ms, "
          f"requests carried: {rep.request_ids}")
    for phase, s in rep.phase_seconds.items():
        print(f"    {phase:15s} {s * 1e3:7.1f} ms")

    n = fleet.run(max_iters=64)
    print(f"== drained in {n} fleet steps ==")
    rec = fleet.record("alice")
    req = next(inst.service.coserve.requests["r0"]
               for inst in fleet.instances.values()
               if "r0" in inst.service.coserve.requests)
    print(f"  alice {rec.state}: {rec.steps_trained}/{STEPS} steps, "
          f"final loss {rec.losses[-1]:.4f}")
    print(f"  r0 {req.state}: tokens {np.asarray(req.tokens_out).tolist()}")
    print(f"  oracle agreement: {fleet.oracle_agreement():.2f}")
    acct = fleet.accounting()
    print("  per-instance:",
          {iid: (v["admitted"], v["migrated_in"], v["migrated_out"])
           for iid, v in acct["instances"].items()})


if __name__ == "__main__":
    main()
