"""Quickstart: multiplex three tenant PEFT tasks on one shared backbone.

Runs on CPU in ~a minute.  Shows the full MuxTune flow:
  tasks -> ExecutionPlanner (fusion/grouping/template/alignment)
        -> ModelGenerator.register_tasks (dynamic adapter attachment)
        -> PEFTEngine (fused spatial batches, temporal interleaving).

  PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs import smoke_config
from repro.core import ExecutionPlanner, ModelGenerator, ParallelismSpec, PEFTEngine
from repro.data import HTaskLoader, make_task
from repro.peft.adapters import ADAPTER_TUNING, LORA
from repro.peft.methods import AdapterConfig


def main():
    # Three tenants: different datasets, PEFT types, ranks, learning rates.
    tasks = [
        make_task("tenant-a", "sst2", micro_batch=2,
                  adapter=AdapterConfig(LORA, rank=8, lr=1e-3), seed=0),
        make_task("tenant-b", "qa", micro_batch=2,
                  adapter=AdapterConfig(LORA, rank=16, lr=5e-4), seed=1),
        make_task("tenant-c", "rte", micro_batch=1,
                  adapter=AdapterConfig(ADAPTER_TUNING, rank=8, lr=1e-3), seed=2),
    ]

    cfg = smoke_config("llama3.2-3b")  # reduced llama-family backbone
    planner = ExecutionPlanner(cfg, ParallelismSpec(num_stages=2, chips_per_stage=1))
    plan = planner.plan(tasks, n_micro=2)

    print("== plan ==")
    for k, v in plan.summary().items():
        print(f"  {k}: {v}")
    for i, h in enumerate(plan.htasks):
        print(f"  hTask{i}: tasks={h.task_ids} rows={h.rows} row_len={h.row_len} "
              f"chunk={h.chunk} effective={h.effective_tokens}/{h.tokens}")

    gen = ModelGenerator(cfg)
    gen.register_tasks(tasks)          # dynamic attachment — no backbone reinit
    engine = PEFTEngine(gen, plan, lr=1e-3)
    loaders = {i: HTaskLoader(tasks, plan.alignment[i], cfg.vocab_size)
               for i in range(len(plan.htasks))}

    print("== training ==")
    for step in range(5):
        m = engine.run_iteration(loaders)
        tp = engine.throughput(m)
        print(f"  step {step}: loss={m.loss:.3f} "
              f"per-task={np.round(m.per_task_loss, 3)} "
              f"tok/s={tp['tokens_per_s']:.0f} eff-tok/s={tp['effective_tokens_per_s']:.0f}")

    # a fourth tenant arrives mid-flight
    print("== tenant-d arrives ==")
    t4 = make_task("tenant-d", "qa", 1, AdapterConfig(LORA, rank=8), seed=3)
    gen.register_tasks([t4])
    plan2 = planner.plan(tasks + [t4], n_micro=2)
    engine2 = PEFTEngine(gen, plan2, lr=1e-3)
    loaders2 = {i: HTaskLoader(tasks + [t4], plan2.alignment[i], cfg.vocab_size)
                for i in range(len(plan2.htasks))}
    m = engine2.run_iteration(loaders2)
    print(f"  step 0 (4 tenants): loss={m.loss:.3f} tasks={len(plan2.tasks)}")
    print("done.")


if __name__ == "__main__":
    main()
