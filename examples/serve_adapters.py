"""Online multi-tenant serving example: the MuxTuneService lifecycle.

Three tenants arrive staggered against ONE running engine instance:
submit (admission-gated hot-attach) -> train (spatially fused iterations)
-> one tenant cancels -> the rest complete -> their adapters checkpoint out
atomically -> a completed tenant resubmits warm-started from its own
checkpoint.

  PYTHONPATH=src python examples/serve_adapters.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import tempfile

from repro.configs import smoke_config
from repro.core.task import ParallelismSpec
from repro.data.synthetic import make_task
from repro.peft.adapters import LORA, VERA
from repro.peft.methods import AdapterConfig
from repro.serve import MuxTuneService


def main():
    cfg = smoke_config("llama3.2-3b")
    ckpt_dir = tempfile.mkdtemp(prefix="muxtune_serve_")
    svc = MuxTuneService(cfg, ParallelismSpec(), lr=1e-3, n_micro=1,
                         ckpt_dir=ckpt_dir, reserve_slots=4)

    print("== tenants arrive staggered ==")
    svc.submit(make_task("alice", "sst2", 2, AdapterConfig(LORA, rank=8), seed=0),
               target_steps=6, priority=1)
    print(f"  t={svc.clock}: alice -> {svc.record('alice').state}")
    svc.step(); svc.step()

    svc.submit(make_task("bob", "qa", 2, AdapterConfig(LORA, rank=4), seed=1),
               target_steps=4)
    print(f"  t={svc.clock}: bob -> {svc.record('bob').state} "
          f"(resident: {svc.resident_ids})")
    svc.step()

    # any registered PEFTMethod co-locates: carol brings VeRA (shared
    # frozen A/B + tiny per-task scaling vectors — multi-tenant friendly)
    svc.submit(make_task("carol", "rte", 1, AdapterConfig(VERA, rank=4),
                         seed=2), target_steps=8)
    print(f"  t={svc.clock}: carol -> {svc.record('carol').state}")
    svc.step()

    print("== carol cancels mid-flight (no checkpoint) ==")
    svc.cancel("carol")
    print(f"  t={svc.clock}: carol -> {svc.record('carol').state}")

    print("== train until alice and bob complete ==")
    svc.run(max_iters=20)
    for tid in ("alice", "bob"):
        rec = svc.record(tid)
        print(f"  {tid}: {rec.state} after {rec.steps_trained} steps, "
              f"loss {rec.losses[0]:.3f} -> {rec.losses[-1]:.3f}, "
              f"eff-token ratio {rec.effective_token_ratio:.2f}, "
              f"checkpoint {rec.checkpoint_path}")

    print("== alice resubmits, warm-started from her checkpoint ==")
    svc.submit(make_task("alice", "sst2", 2, AdapterConfig(LORA, rank=8), seed=0),
               target_steps=2, warm_start_dir=f"{ckpt_dir}/alice")
    svc.run(max_iters=10)
    rec = svc.record("alice")
    print(f"  alice: {rec.state}, warm-start loss {rec.losses[0]:.3f} "
          f"(vs cold {5.5:.1f}-ish)")

    acct = svc.accounting()
    print(f"== accounting: {acct['completed']} completions, "
          f"{acct['replans']} re-plans, "
          f"step-cache {acct['cache_hits']} hits / {acct['cache_misses']} misses, "
          f"peak Eq.5 memory {acct['peak_stage_memory'] / 2**20:.1f} MiB ==")
    assert acct["peak_stage_memory"] <= acct["memory_budget"]
    print("done.")


if __name__ == "__main__":
    main()
