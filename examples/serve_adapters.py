"""Serving example: batched decode with a KV cache over the shared backbone.

Demonstrates the serve path the decode_* dry-run cells lower: init a decode
state, prefill a short prompt token-by-token, then decode continuations for
a batch of requests.

  PYTHONPATH=src python examples/serve_adapters.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models.transformer import build_model


def main():
    cfg = smoke_config("llama3.2-3b")
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)

    B, prompt_len, gen_len, max_len = 4, 8, 16, 32
    prompts = jax.random.randint(key, (B, prompt_len), 0, cfg.vocab_size)

    serve_step = jax.jit(model.decode_step, donate_argnums=(1,))
    state = model.init_decode_state(params, B, max_len)

    print(f"== serving {B} requests (prompt {prompt_len}, gen {gen_len}) ==")
    t0 = time.perf_counter()
    # prefill token-by-token through the decode path (cache warms up)
    logits = None
    for t in range(prompt_len):
        logits, state = serve_step(params, state, prompts[:, t : t + 1])
    # greedy decode
    outs = []
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    for _ in range(gen_len):
        outs.append(np.asarray(tok)[:, 0])
        logits, state = serve_step(params, state, tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    dt = time.perf_counter() - t0
    gen = np.stack(outs, axis=1)
    print(f"  generated {B}x{gen_len} tokens in {dt:.2f}s "
          f"({B * (prompt_len + gen_len) / dt:.0f} tok/s incl. compile)")
    for b in range(B):
        print(f"  req{b}: {gen[b].tolist()}")
    assert int(state["pos"]) == prompt_len + gen_len
    print("done.")


if __name__ == "__main__":
    main()
