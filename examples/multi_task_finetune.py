"""End-to-end driver: train a ~100M-param llama-family backbone with four
multiplexed PEFT tenants for a few hundred steps, with checkpoint/restart.

  PYTHONPATH=src python examples/multi_task_finetune.py --steps 200

This is the deliverable-(b) end-to-end run: real model, real data pipeline
(packed + chunk-aligned), per-task optimizer isolation, async checkpoints.
Use --steps 20 for a quick pass.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args, _ = ap.parse_known_args()
    sys.argv = [
        "train",
        "--arch", "smollm-360m",
        "--scale", "0.75",            # ~100M params (d=704, 24 layers)
        "--steps", str(args.steps),
        "--micro-batch", "4",
        "--lr", "2e-3",
        "--tasks", "sst2:lora:8,qa:lora:16,rte:adapter:8,sst2:ia3",
        "--ckpt-dir", "/tmp/muxtune_e2e_ckpt",
        "--ckpt-every", "25",
    ]
    train_main()


if __name__ == "__main__":
    main()
